"""Core-module unit tests: modes, buckets, admission, traffic, exposure."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionPlan, AggregationMode, Commander,
                        CusumGuard, GroupPolicy, GroupRules,
                        Predictor, Schedule, Supervisor, assign_groups,
                        bits_per_element, group_sizes,
                        group_cosines_from_workers, plan_traffic_ratio,
                        resolve_policies, wire_bytes_per_device)
from repro.core.exposure import ExposureModel, envelope_sweep


# ---------------------------------------------------------------------------
# vote_psum margin accumulation (regression: int8 psum wrapped for W >= 128)
# ---------------------------------------------------------------------------

def test_vote_psum_majority_correct_at_w256():
    """W=256 virtual workers: the vote margin spans [-256, 256], which
    wrapped the old int8 psum (e.g. 256 unanimous votes -> margin 0, and
    margin +128 -> -128, flipping the majority).  Votes must be widened
    to int32 before the reduction."""
    from repro.core import lowbit_vote_psum

    w, n = 256, 6
    # per-element count of positive votes; margins 2c - W hit the int8
    # wrap points: 256 -> 0, 192 -> +128 (int8: -128), 64 -> -128, etc.
    pos_counts = np.array([256, 192, 129, 127, 64, 0])
    g = np.full((w, n), -1.0, np.float32)
    for i, c in enumerate(pos_counts):
        g[:c, i] = 1.0
    # shuffle workers per element so the pattern isn't degenerate
    rng = np.random.RandomState(0)
    for i in range(n):
        rng.shuffle(g[:, i])

    u = jax.vmap(
        lambda x: lowbit_vote_psum(x, "w", w)[0],
        axis_name="w")(jnp.asarray(g))
    want = np.sign(2 * pos_counts - w).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(u[0]), want)


# ---------------------------------------------------------------------------
# bucket manager / group rules
# ---------------------------------------------------------------------------

def _fake_params():
    z = lambda *s: jnp.zeros(s)
    return {
        "embed": {"tok": z(64, 8)},
        "layers": {"attn": {"wq": z(8, 8), "q_bias": z(8)},
                   "moe": {"router": z(8, 4), "w_up": z(4, 8, 16)},
                   "norm1": {"scale": z(8)}},
        "head": {"w": z(8, 64)},
    }


def test_group_rules_assignment():
    groups = assign_groups(_fake_params())
    assert groups["head"]["w"] == "head"
    assert groups["layers"]["moe"]["router"] == "head"
    assert groups["layers"]["moe"]["w_up"] == "backbone"
    assert groups["layers"]["attn"]["wq"] == "backbone"
    assert groups["layers"]["attn"]["q_bias"] == "norms"
    assert groups["layers"]["norm1"]["scale"] == "norms"
    assert groups["embed"]["tok"] == "embed"


def test_resolve_policies_modes():
    params = _fake_params()
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    pol = resolve_policies(params, plan)
    assert pol["layers"]["attn"]["wq"].mode == AggregationMode.G_BINARY
    assert pol["head"]["w"].mode == AggregationMode.FP32
    assert pol["layers"]["norm1"]["scale"].mode == AggregationMode.FP32


def test_plan_signature_stable_and_distinct():
    a = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    b = AdmissionPlan.lowbit_backbone(AggregationMode.G_TERNARY)
    assert a.signature() == AdmissionPlan.lowbit_backbone(
        AggregationMode.G_BINARY).signature()
    assert a.signature() != b.signature()
    assert a.signature() != AdmissionPlan.fp32_all().signature()


# ---------------------------------------------------------------------------
# paper Table 6 accounting
# ---------------------------------------------------------------------------

def test_table6_traffic_ratios():
    """ResNet-18/CIFAR-100 group sizes reproduce the paper's ratios."""
    head = 512 * 100 + 100
    sizes = {"backbone": 11_220_132 - head, "head": head}
    rows = [
        (AdmissionPlan.lowbit_all(AggregationMode.G_BINARY), 0.0313),
        (AdmissionPlan.lowbit_all(AggregationMode.G_TERNARY), 0.0494),
        (AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY), 0.0357),
        (AdmissionPlan.lowbit_backbone(AggregationMode.G_TERNARY), 0.0537),
        (AdmissionPlan.fp32_all(), 1.0),
    ]
    for plan, want in rows:
        got = plan_traffic_ratio(sizes, plan)
        assert abs(got - want) < 0.0035, (plan.signature(), got, want)


def test_wire_bytes_ordering():
    """packed_a2a < vote_psum < fp32 for any size and worker count."""
    for n in (1 << 16, 1 << 24):
        for w in (8, 32, 256):
            f = wire_bytes_per_device(n, AggregationMode.FP32, Schedule.PSUM, w)
            v = wire_bytes_per_device(n, AggregationMode.G_BINARY,
                                      Schedule.VOTE_PSUM, w)
            p = wire_bytes_per_device(n, AggregationMode.G_BINARY,
                                      Schedule.PACKED_A2A, w)
            assert p < v < f
            assert f / v == pytest.approx(4.0)
            assert f / p == pytest.approx(64 / 3, rel=0.01)  # ~21.3x


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------

def test_commander_ladder():
    cmd = Commander(tau_binary=0.35, tau_ternary=0.30)
    plan = cmd.propose({
        "backbone": {"gbinary": 0.72, "gternary": 0.59},
        "head": {"gbinary": 0.17, "gternary": 0.14},
        "norms": {"gbinary": 0.9, "gternary": 0.9},
        "embed": {"gbinary": 0.33, "gternary": 0.31},
    })
    assert plan.policy_for("backbone").mode == AggregationMode.G_BINARY
    assert plan.policy_for("head").mode == AggregationMode.FP32
    assert plan.policy_for("norms").mode == AggregationMode.FP32   # always
    assert plan.policy_for("embed").mode == AggregationMode.G_TERNARY


def test_control_plane_warmup_admit_recover_readmit():
    from repro.fabric import PaperController, Telemetry
    cp = PaperController(warmup_steps=5,
                         supervisor=Supervisor(
                             guard=CusumGuard(kappa=0.0, h=0.3),
                             cooldown_steps=5))
    steps = iter(range(1, 10_000))

    def observe(loss, cosines=None):
        return cp.observe(Telemetry(step=next(steps), loss=loss,
                                    cosines=cosines))

    cos = {"backbone": {"gbinary": 0.8, "gternary": 0.7},
           "head": {"gbinary": 0.1, "gternary": 0.1}}
    # warm-up: FP32
    for i in range(4):
        plan = observe(1.0 - 0.01 * i)
        assert plan.signature() == AdmissionPlan.fp32_all().signature()
    plan = observe(0.9, cosines=cos)   # step 5: admission
    assert plan.policy_for("backbone").mode == AggregationMode.G_BINARY
    assert plan.policy_for("head").mode == AggregationMode.FP32
    # degradation window -> recovery
    recovered = False
    for i in range(10):
        plan = observe(0.9 + 0.2 * (i + 1))
        if plan.signature() == AdmissionPlan.fp32_all().signature():
            recovered = True
            break
    assert recovered
    kinds = [e.kind for e in cp.events]
    assert "admitted" in kinds and "recovery" in kinds
    # healthy again -> re-admission after cooldown
    for i in range(20):
        plan = observe(0.5, cosines=cos)
    assert plan.policy_for("backbone").mode == AggregationMode.G_BINARY
    assert "readmitted" in [e.kind for e in cp.events]


def test_predictor_forecast():
    pred = Predictor(num_workers=32)
    sizes = {"backbone": 10_000_000, "head": 50_000}
    fp32 = pred.forecast(sizes, AdmissionPlan.fp32_all())
    lb = pred.forecast(sizes, AdmissionPlan.lowbit_backbone(
        AggregationMode.G_BINARY, schedule=Schedule.PACKED_A2A))
    assert lb["allreduce_time_s"] < fp32["allreduce_time_s"]
    assert lb["traffic_ratio"] < 0.04
    assert fp32["traffic_ratio"] == 1.0


# ---------------------------------------------------------------------------
# cosine diagnostics (Table 5 structure)
# ---------------------------------------------------------------------------

def test_cosine_diagnostics_separate_aligned_from_misaligned(rng):
    """Aligned workers -> high cosine; heavy-tailed minority-magnitude
    gradients (one large worker vs many small opposite ones — the regime
    behind the paper's weak classifier-head alignment) -> low/negative."""
    w, n = 8, 4096
    base = rng.randn(n).astype(np.float32)
    aligned = np.stack([base + 0.3 * rng.randn(n) for _ in range(w)])
    mag = np.abs(rng.randn(n)).astype(np.float32) + 0.1
    heavy = np.stack([10.0 * mag] + [-0.1 * mag] * (w - 1))  # mean>0, majority<0
    grads = {"layers": {"w": jnp.asarray(aligned)},
             "head": {"w": jnp.asarray(heavy)}}
    groups = {"layers": {"w": "backbone"}, "head": {"w": "head"}}
    cos = group_cosines_from_workers(grads, groups)
    assert float(cos["backbone"]["gbinary"]) > 0.5
    assert float(cos["head"]["gbinary"]) < 0.0


# ---------------------------------------------------------------------------
# exposure model (paper Section 5 structure)
# ---------------------------------------------------------------------------

def test_exposure_hidden_under_bandwidth_pressure():
    m = ExposureModel()
    n = 8 << 20
    r_busy = m.exposed(n, 32, wire_bytes_per_device=3 * n / 8)
    assert r_busy["hidden"], r_busy
    # tiny collective (cheap service) exposes the datapath
    r_idle = m.exposed(n, 32, wire_bytes_per_device=1024)
    assert r_idle["t_exposed_s"] > 0


def test_envelope_sweep_shape():
    rows = envelope_sweep()
    assert set(rows) == {"a", "b", "c", "d"}
    assert all(len(v) > 0 for v in rows.values())
    # panel (a): deeper datapaths expose more at higher bandwidth
    deep = [r for r in rows["a"] if r["depth_mult"] == 4.0]
    shallow = [r for r in rows["a"] if r["depth_mult"] == 1.0]
    assert max(r["exposed_pct"] for r in deep) >= \
        max(r["exposed_pct"] for r in shallow)
