"""Per-kernel allclose sweeps against the pure-jnp oracles (paper Section 6).

The paper's functional-correctness protocol: mode-specific expected values —
identity uses byte-exact read-back, G-Binary/G-Ternary use a
transformation-aware oracle computing the Section 2 reduction.  Here every
Pallas kernel (interpret mode on CPU) is swept over shapes/dtypes and
compared bit-exactly against kernels/ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.kernels import ref

SHAPES = [(32, 128), (64, 128), (256, 128), (1024, 128), (4096, 128)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


@pytest.mark.parametrize("m,lane", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sign_pack_matches_ref(rng, m, lane, dtype):
    x = jnp.asarray(rng.randn(m, lane), dtype)
    got = K.pack_signs(x, interpret=True)
    want = ref.sign_pack(x)
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,lane", SHAPES[:4])
@pytest.mark.parametrize("w", [2, 3, 8, 16, 32])
def test_popcount_stack_matches_ref(rng, m, lane, w):
    planes = [jnp.asarray(rng.randn(m, lane), jnp.float32) for _ in range(w)]
    stack = jnp.stack([K.pack_signs(p, interpret=True) for p in planes])
    got = K.popcount_stack(stack, interpret=True)
    want = ref.popcount_stack(stack)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # counts bounded by W
    assert int(np.asarray(got).max()) <= w


@pytest.mark.parametrize("m", [32, 256, 1024])
@pytest.mark.parametrize("w", [3, 8, 32])
@pytest.mark.parametrize("gated", [False, True])
def test_majority_decode_matches_ref(rng, m, w, gated):
    counts = jnp.asarray(rng.randint(0, w + 1, (m, 128)), jnp.int8)
    gate = K.ternary_gate_words(m) if gated else None
    gs, gm = K.majority_decode(counts, num_workers=w, gate_words=gate, interpret=True)
    rs, rm = ref.majority_decode(counts, w, gate)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(rm))


@pytest.mark.parametrize("m", [32, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_unpack_ternary_matches_ref(rng, m, dtype):
    counts = jnp.asarray(rng.randint(0, 9, (m, 128)), jnp.int8)
    sw, mw = K.majority_decode(counts, num_workers=8)
    got = K.unpack_ternary(sw, mw, dtype=dtype, interpret=True)
    want = ref.unpack_ternary(sw, mw, dtype=dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    vals = set(np.unique(np.asarray(got, np.float32)))
    assert vals <= {-1.0, 0.0, 1.0}


@pytest.mark.parametrize("m", [32, 1024])
@pytest.mark.parametrize("scale", [0.1, 1.0, 1e-3])
def test_apply_sign_update_matches_ref(rng, m, scale):
    param = jnp.asarray(rng.randn(m, 128), jnp.float32)
    counts = jnp.asarray(rng.randint(0, 9, (m, 128)), jnp.int8)
    sw, mw = K.majority_decode(counts, num_workers=8,
                               gate_words=K.ternary_gate_words(m))
    got = K.apply_sign_update(param, sw, mw, scale, interpret=True)
    want = ref.apply_sign_update(param, sw, mw, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_end_to_end_packed_pipeline_equals_dense_oracle(rng):
    """pack -> popcount -> majority -> unpack == the Section 2 equations."""
    w, n = 8, 32 * 128 * 3
    grads = rng.randn(w, n).astype(np.float32)
    planes = [ref.to_plane(jnp.asarray(grads[i])) for i in range(w)]
    stack = jnp.stack([K.pack_signs(p, interpret=True) for p in planes])
    counts = K.popcount_stack(stack, interpret=True)
    # G-Binary
    sw, mw = K.majority_decode(counts, num_workers=w, interpret=True)
    u = ref.from_plane(K.unpack_ternary(sw, mw, interpret=True), n)
    want = ref.gbinary_aggregate_dense(jnp.asarray(grads))
    np.testing.assert_array_equal(np.asarray(u), np.asarray(want))
    # G-Ternary (2-of-3 gate)
    sw, mw = K.majority_decode(counts, num_workers=w,
                               gate_words=K.ternary_gate_words(planes[0].shape[0]), interpret=True)
    u = ref.from_plane(K.unpack_ternary(sw, mw, interpret=True), n)
    want = ref.gternary_aggregate_dense(jnp.asarray(grads))
    np.testing.assert_array_equal(np.asarray(u), np.asarray(want))


def test_identity_readback_byte_exact(rng):
    """Identity mode: packed payload written and read back byte-for-byte."""
    x = jnp.asarray(rng.randn(256, 128), jnp.float32)
    words = K.pack_signs(x, interpret=True)
    roundtrip = jnp.asarray(np.asarray(words))   # host write + read back
    np.testing.assert_array_equal(np.asarray(words), np.asarray(roundtrip))


@pytest.mark.parametrize("w", [128, 256])
def test_popcount_counts_survive_wide_worker_groups(rng, w):
    """Regression: the int8 count accumulator wrapped for W > 127 (256
    unanimous positive votes counted as 0, flipping the majority)."""
    plane = jnp.ones((32, 128), jnp.float32)
    stack = jnp.stack([K.pack_signs(plane, interpret=True)] * w)
    counts = K.popcount_stack(stack, interpret=True)
    assert counts.dtype == jnp.int32
    assert int(np.asarray(counts).min()) == w       # unanimous -> count == W
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(ref.popcount_stack(stack)))
    sw, mw = K.majority_decode(counts, num_workers=w, interpret=True)
    u = K.unpack_ternary(sw, mw, interpret=True)
    assert np.all(np.asarray(u) == 1.0)


def test_vote_tie_decodes_to_zero():
    """Even worker count, exact tie -> a = 0 -> u = 0 (paper Section 2)."""
    w = 8
    grads = np.ones((w, 32 * 128), np.float32)
    grads[: w // 2] *= -1.0
    planes = [ref.to_plane(jnp.asarray(g)) for g in grads]
    stack = jnp.stack([K.pack_signs(p, interpret=True) for p in planes])
    counts = K.popcount_stack(stack, interpret=True)
    sw, mw = K.majority_decode(counts, num_workers=w, interpret=True)
    u = K.unpack_ternary(sw, mw, interpret=True)
    assert np.all(np.asarray(u) == 0.0)
