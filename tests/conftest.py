"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; multi-device tests spawn subprocesses or use
their own flags via module isolation (tests/test_distributed.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)


import jax
import jax.sharding

#: the partial-manual shard_map runtime needs the jax >= 0.7 API surface
JAX_CAPABLE = (hasattr(jax, "shard_map") and hasattr(jax, "set_mesh")
               and hasattr(jax.sharding, "AxisType"))
needs_modern_jax = pytest.mark.skipif(
    not JAX_CAPABLE,
    reason="installed jax lacks shard_map/set_mesh/AxisType (needs >= 0.7)")
