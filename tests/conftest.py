"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; multi-device tests spawn subprocesses or use
their own flags via module isolation (tests/test_distributed.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
