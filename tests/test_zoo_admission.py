"""Config-zoo admission dryrun: layer-aware admission beyond two cells.

The paper validates admission on one vision and one NLP model; the zoo
sweep (ISSUE 8 satellite / ROADMAP item 4) demonstrates the same
layer-aware plan machinery across four heterogeneous architectures —
MoE (routers), hybrid attention/SSM, pure SSM (xLSTM), and an
encoder-decoder audio model — without touching a device: abstract
params, the Commander ladder on synthetic calibration cosines, bucket
planning, traffic accounting, and one DES replay per architecture.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (AggregationMode, Commander, codec_name,
                        plan_traffic_ratio)
from repro.fabric import Fabric
from repro.models import init_params
from repro.sim import simulate_layout

ZOO = ("deepseek_moe_16b", "hymba_1p5b", "xlstm_125m", "whisper_tiny")

#: healthy calibration: backbone sign-alignment passes the binary rung
_COSINES = {"backbone": {"gbinary": 0.9, "gternary": 0.85},
            "embed": {"gbinary": 0.9, "gternary": 0.85},
            "head": {"gbinary": 0.9, "gternary": 0.85},
            "norms": {"gbinary": 0.9, "gternary": 0.85}}


@pytest.fixture(scope="module")
def fabric():
    return Fabric(num_workers=8)


@pytest.mark.parametrize("arch", ZOO)
def test_zoo_admission_dryrun(arch, fabric):
    cfg = get_config(arch, smoke=True)
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    sizes = fabric.group_sizes(params)
    assert "backbone" in sizes and sizes["backbone"] > 0

    # admission: present only the groups this architecture actually has
    cosines = {g: _COSINES[g] for g in sizes}
    plan = Commander().propose(cosines)
    assert codec_name(plan.policy_for("backbone").mode) == "gbinary"
    # scale-critical groups never admit, whatever their cosines say
    assert plan.policy_for("norms").mode is AggregationMode.FP32
    # default (unlisted groups) stays on the FP32 bypass
    assert plan.default.mode is AggregationMode.FP32

    # bucket planning fuses the admitted backbone into few launches
    layout = fabric.layout_for(params, plan)
    num_leaves = len(jax.tree.leaves(params))
    assert 0 < layout.num_launches <= num_leaves

    # traffic: strictly below FP32, strictly above zero
    ratio = plan_traffic_ratio(sizes, plan)
    assert 0.0 < ratio < 1.0, (arch, ratio)

    # the admitted layout replays through the DES on a CXL topology
    rep = simulate_layout(layout, fabric.num_workers,
                          topology="cxl_switched", compute_time_s=1e-3)
    assert rep.num_launches == layout.num_launches
    assert rep.step_time_s > 0.0
    assert 0.0 <= rep.exposed_pct <= 100.0


@pytest.mark.parametrize("arch", ZOO)
def test_zoo_routers_and_heads_grouped_head(arch, fabric):
    """MoE routers / output heads land in the never-admitted groups."""
    cfg = get_config(arch, smoke=True)
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    sizes = fabric.group_sizes(params)
    if cfg.moe is not None:
        assert "head" in sizes, f"{arch}: router leaves must map to head"
    # every group the rules produce is coverable by the Commander table
    assert set(sizes) <= set(_COSINES)
