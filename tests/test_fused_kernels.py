"""Codec-owned fused Pallas kernels (repro.kernels.fused).

The contract under test: every fused kernel is **bit-identical** to the
staged reference composition wherever the staged path runs — per stage
(hypothesis round trips on ragged sizes, W in {3, 31, 128, 256}), and
end-to-end through the Fabric session (``fused_kernels`` True vs False,
EF on/off, fused buckets and per-leaf, flat and hierarchical routes).
Comparisons against the jnp reference jit the reference side: XLA CPU
rounds an eager scalar division differently from the jitted program the
kernels (and every production step) run in, and bit-identity is a claim
about compiled programs (DESIGN.md section 12).

Also covered: the KernelSet launch/HBM accounting invariants the
nightly benchmark gate relies on, the ``layout_kernel_stats`` roll-up,
the sim lane pricing (``CodecLane.fused``), the step/layout cache keys,
and the import-hygiene rule that only :mod:`repro.kernels` touches
``kernels.ref`` directly.
"""
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdmissionPlan, init_ef_states, resolve_policies
from repro.fabric import (Fabric, HopPlan, HopSpec, get_codec,
                          layout_kernel_stats, register_hop_plan,
                          unregister_hop_plan)
from repro.kernels import (Int4KernelSet, TopKKernelSet, VoteKernelSet,
                           fused, ref, vote_kernel_set)

#: the satellite's worker-count sweep (odd, large, power-of-two, > ports)
W_SWEEP = [3, 31, 128, 256]


def _tree_equal(a, b):
    flags = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b)
    return all(jax.tree.leaves(flags))


def _grads(rng, w=None):
    mk = (lambda *s: jnp.asarray(rng.randn(*s), jnp.float32)) if w is None \
        else (lambda *s: jnp.asarray(rng.randn(w, *s), jnp.float32))
    return {"backbone": {"w1": mk(40, 33), "w2": mk(257), "w3": mk(64, 8)},
            "head": {"w": mk(17)},
            "norms": {"scale": mk(33)}}


def _stack_planes(rng, w, n):
    """(W, n) random values -> (W, M, LANE) canonical value planes."""
    vals = jnp.asarray(rng.randn(w, n), jnp.float32)
    return jnp.stack([ref.to_plane(vals[i]) for i in range(w)])


# ---------------------------------------------------------------------------
# per-stage bit-identity: fused kernel vs jitted reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", W_SWEEP)
@pytest.mark.parametrize("ternary", [False, True])
def test_vote_pipeline_matches_ref_w_sweep(rng, w, ternary):
    n = 5000                                    # ragged: pads to 2 tiles
    stack = _stack_planes(rng, w, n)
    num_words = stack.shape[1] // ref.PACK
    gate = fused.local_gate_words(num_words, ternary=ternary)
    want = jax.jit(ref.vote_pipeline_dense, static_argnums=1)(
        stack, w, gate).astype(jnp.float32)
    got = fused.vote_pipeline(stack, gate, num_workers=w, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("w", W_SWEEP)
def test_vote_combine_matches_ref_w_sweep(rng, w):
    n = 4096 * 3
    stack = _stack_planes(rng, w, n)
    routed = jnp.stack([ref.sign_pack(stack[i]) for i in range(w)])
    gate = fused.local_gate_words(routed.shape[1], ternary=True, gate_phase=1)
    want_s, want_m = jax.jit(ref.vote_combine, static_argnums=1)(
        routed, w, gate)
    got_s, got_m = fused.vote_combine(routed, gate, num_workers=w,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(want_s), np.asarray(got_s))
    np.testing.assert_array_equal(np.asarray(want_m), np.asarray(got_m))


def test_encode_pack_ef_matches_ref(rng):
    g = ref.to_plane(jnp.asarray(rng.randn(7000), jnp.float32))
    e = ref.to_plane(jnp.asarray(rng.randn(7000), jnp.float32))
    want_w, want_g = jax.jit(ref.encode_pack_ef)(g, e)
    got_w, got_g = fused.encode_pack_ef(g, e, interpret=True)
    np.testing.assert_array_equal(np.asarray(want_w), np.asarray(got_w))
    np.testing.assert_array_equal(np.asarray(want_g), np.asarray(got_g))


def test_ef_residual_matches_ref(rng):
    plane = ref.to_plane(jnp.asarray(rng.randn(9000), jnp.float32))
    beta = jnp.float32(0.7315)
    want = jax.jit(ref.ef_residual)(plane, beta)
    got = fused.ef_residual_plane(plane, beta, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_int4_quant_matches_jitted_ref(rng):
    plane = ref.to_plane(jnp.asarray(rng.randn(5 * 4096), jnp.float32))
    want = jax.jit(ref.int4_quant_plane)(plane)
    got = fused.int4_quant_plane(plane, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_threshold_mask_matches_ref(rng):
    flat = jnp.asarray(rng.randn(6000), jnp.float32)
    plane = ref.to_plane(flat)
    t = jax.lax.top_k(jnp.abs(flat), 600)[0][-1]
    want = jax.jit(ref.threshold_mask_plane)(plane, t)
    got = fused.threshold_mask_plane(plane, t, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# Fabric end-to-end: fused_kernels True vs False, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interpret", [None, True])
@pytest.mark.parametrize("mode", ["gbinary", "gternary"])
@pytest.mark.parametrize("error_feedback", [False, True])
@pytest.mark.parametrize("fused_buckets", [True, False])
def test_fabric_fused_kernels_bit_identical_packed(rng, interpret, mode,
                                                   error_feedback,
                                                   fused_buckets):
    w = 4
    gs = _grads(rng, w=w)
    plan = AdmissionPlan.lowbit_backbone(mode, schedule="packed_a2a",
                                         error_feedback=error_feedback)
    f_on = Fabric(dp_axes=("w",), num_workers=w, interpret=interpret,
                  fused_kernels=True)
    f_off = Fabric(dp_axes=("w",), num_workers=w, interpret=interpret,
                   fused_kernels=False)
    g0 = jax.tree.map(lambda x: x[0], gs)
    if error_feedback:
        ef0 = init_ef_states(g0, f_on.resolve(g0, plan))
        efs = jax.tree.map(
            lambda e: (jnp.asarray(rng.randn(w, *e.shape), jnp.float32)
                       if e.ndim > 0 else jnp.zeros((w,) + e.shape)), ef0)
    else:
        efs = None

    def run(f):
        def one(g, e):
            return f.aggregate(g, plan, ef=e, fused=fused_buckets)
        if efs is None:
            return jax.vmap(lambda g: one(g, None), axis_name="w")(gs)
        return jax.vmap(one, axis_name="w")(gs, efs)

    want, want_ef = run(f_off)
    got, got_ef = run(f_on)
    assert _tree_equal(want, got)
    if error_feedback:
        assert _tree_equal(want_ef, got_ef)


@pytest.mark.parametrize("mode", ["int4", "topk"])
def test_fabric_fused_kernels_bit_identical_means(rng, mode):
    """Mean codecs: kernel encode == jnp encode inside one jit program."""
    gs = _grads(rng)
    plan = AdmissionPlan.lowbit_backbone(mode)
    f_on = Fabric(interpret=True, fused_kernels=True)
    f_off = Fabric(interpret=True, fused_kernels=False)
    pol = f_on.resolve(gs, plan)
    a_on = jax.jit(lambda g: f_on.aggregate(g, pol)[0])(gs)
    a_off = jax.jit(lambda g: f_off.aggregate(g, pol)[0])(gs)
    assert _tree_equal(a_on, a_off)


@pytest.mark.parametrize("mode", ["gbinary", "gternary"])
def test_fabric_host_local_single_launch_pipeline(rng, mode):
    """Host-local packed vote: the fused path is ONE vote_pipeline kernel;
    still bit-identical to the staged local fallback."""
    gs = _grads(rng)
    plan = AdmissionPlan.lowbit_backbone(mode, schedule="packed_a2a")
    f_on = Fabric(interpret=True, fused_kernels=True)
    f_off = Fabric(interpret=True, fused_kernels=False)
    pol = f_on.resolve(gs, plan)
    # jit: the staged path's empty-axes all_to_all only lowers inside a
    # compiled program (and production steps are always jitted)
    a_on = jax.jit(lambda g: f_on.aggregate(g, pol)[0])(gs)
    a_off = jax.jit(lambda g: f_off.aggregate(g, pol)[0])(gs)
    assert _tree_equal(a_on, a_off)


def test_fabric_vote_psum_ignores_kernel_sets(rng):
    """Dense vote_psum has no packed stages to fuse: fused_kernels is a
    no-op there by design (documented in backends.py)."""
    gs = _grads(rng)
    plan = AdmissionPlan.lowbit_backbone("gbinary")      # default vote_psum
    a_on, _ = Fabric(fused_kernels=True).aggregate(gs, plan)
    a_off, _ = Fabric(fused_kernels=False).aggregate(gs, plan)
    assert _tree_equal(a_on, a_off)


@pytest.mark.parametrize("error_feedback", [False, True])
def test_fabric_hierarchical_hop_kernels_bit_identical(rng, error_feedback):
    """Per-hop kernel resolution: a 2-hop fp32 -> gbinary/packed_a2a route
    aggregates bit-identically with kernels on and off."""
    outer, inner = 2, 2
    w = outer * inner
    gs = jax.tree.map(
        lambda x: jnp.reshape(x, (outer, inner) + x.shape[1:]),
        _grads(rng, w=w))
    register_hop_plan(HopPlan("fk_hier", (
        HopSpec("fp32", workers=inner),
        HopSpec("gbinary", schedule="packed_a2a"))))
    try:
        plan = AdmissionPlan.lowbit_all("fk_hier",
                                        error_feedback=error_feedback)
        g0 = jax.tree.map(lambda x: x[0, 0], gs)
        ef0 = init_ef_states(g0, resolve_policies(g0, plan))
        efs = jax.tree.map(
            lambda e: (jnp.asarray(rng.randn(outer, inner, *e.shape),
                                   e.dtype) if e.ndim > 0
                       else jnp.zeros((outer, inner) + e.shape)), ef0)

        def run(fused_kernels):
            f = Fabric(dp_axes=("outer", "inner"), num_workers=w,
                       fused_kernels=fused_kernels)

            def one(g, e):
                return f.aggregate(
                    g, plan, ef=(e if error_feedback else None))
            return jax.vmap(jax.vmap(one, axis_name="inner"),
                            axis_name="outer")(gs, efs)

        want, want_ef = run(False)
        got, got_ef = run(True)
        assert _tree_equal(want, got)
        if error_feedback:
            assert _tree_equal(want_ef, got_ef)
    finally:
        unregister_hop_plan("fk_hier")


def test_fused_local_packed_matches_vote_psum_semantics(rng):
    """W=1 host-local: the single-kernel pipeline degenerates to
    sign-with-zero-gate of the lone worker — the dense oracle."""
    g = jnp.asarray(rng.randn(517), jnp.float32)
    u, _ = fused.fused_packed_vote(g, (), 1, ternary=True, interpret=True)
    want = np.asarray(ref.gternary_aggregate_dense(g[None].reshape(1, -1)))
    np.testing.assert_array_equal(np.asarray(u).reshape(-1), want.reshape(-1))


# ---------------------------------------------------------------------------
# accounting invariants (the nightly gate's contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ks", [VoteKernelSet(), Int4KernelSet(),
                                TopKKernelSet(1 / 16)],
                         ids=["vote", "int4", "topk"])
@pytest.mark.parametrize("distributed", [True, False])
@pytest.mark.parametrize("ef", [False, True])
def test_kernel_set_accounting_invariants(ks, distributed, ef):
    n, w = 1 << 20, 32
    lf = ks.launches(fused=True, distributed=distributed, ef=ef)
    lu = ks.launches(fused=False, distributed=distributed, ef=ef)
    bf = ks.hbm_bytes(n, num_workers=w, fused=True,
                      distributed=distributed, ef=ef)
    bu = ks.hbm_bytes(n, num_workers=w, fused=False,
                      distributed=distributed, ef=ef)
    assert lf < lu, "fused must launch strictly fewer kernels"
    assert bf <= bu, "fused must model no more HBM traffic"
    assert lf >= 1 and bf > 0


def test_vote_kernel_set_is_shared_singleton():
    assert vote_kernel_set() is vote_kernel_set()
    assert get_codec("gbinary").pallas_kernels() is \
        get_codec("gternary").pallas_kernels()


def test_layout_kernel_stats_rollup(rng):
    gs = _grads(rng)
    plan = AdmissionPlan.lowbit_backbone("gbinary", schedule="packed_a2a")
    f = Fabric(num_workers=32)
    stats = layout_kernel_stats(f.layout_for(gs, plan), 32)
    assert stats["collectives"] == f.layout_for(gs, plan).num_launches
    assert stats["launches_fused"] < stats["launches_unfused"]
    assert stats["hbm_bytes_fused"] <= stats["hbm_bytes_unfused"]
    assert stats["unkernelized"] >= 1            # the fp32 head bucket
    # hierarchical: per-hop decomposition (fp32 hop unkernelized,
    # backbone vote hop priced at its own group size)
    register_hop_plan(HopPlan("fk_stats", (
        HopSpec("fp32", workers=8),
        HopSpec("gbinary", schedule="packed_a2a"))))
    try:
        hplan = AdmissionPlan.lowbit_backbone("fk_stats")
        hstats = layout_kernel_stats(f.layout_for(gs, hplan), 32)
        assert hstats["launches_fused"] < hstats["launches_unfused"]
    finally:
        unregister_hop_plan("fk_stats")


# ---------------------------------------------------------------------------
# session integration: context flag + cache keys + signatures
# ---------------------------------------------------------------------------

def test_context_carries_fused_kernels_flag():
    assert Fabric().context.fused_kernels is True
    assert Fabric(fused_kernels=False).context.fused_kernels is False


def test_kernel_signatures():
    assert get_codec("gbinary").kernel_signature() == "vote:v1"
    assert get_codec("gternary").kernel_signature() == "vote:v1"
    assert get_codec("fp32").kernel_signature() is None
    assert "levels=7" in get_codec("int4").kernel_signature()
    # hierarchical: composed over hops, '-' for kernel-less legs
    sig = get_codec("hier_fp32_gbinary").kernel_signature()
    assert sig == "->vote:v1"


def test_layout_cache_distinguishes_kernel_signatures(rng):
    """Swapping a codec's kernels under the same name must miss the
    layout cache (the signature participates in the key)."""
    gs = _grads(rng)
    plan = AdmissionPlan.lowbit_backbone("int4")
    f = Fabric(num_workers=4)
    l1 = f.layout_for(gs, plan)
    codec = get_codec("int4")
    orig = codec.pallas_kernels
    try:
        Int4Codec = type(codec)
        Int4Codec.pallas_kernels = lambda self: Int4KernelSet(levels=3.0)
        l2 = f.layout_for(gs, plan)
    finally:
        type(codec).pallas_kernels = orig
    assert len(f._layouts) == 2
    assert l1 is not l2


# ---------------------------------------------------------------------------
# sim lane pricing (CodecLane.fused -> FlitPipeline.unfused_passes)
# ---------------------------------------------------------------------------

def test_builtin_lanes_all_fused_and_pricing_unchanged():
    from repro.fabric import available_codecs
    from repro.sim import FlitPipeline
    pipe = FlitPipeline()
    for name in available_codecs():
        lane = get_codec(name).lane
        assert lane.fused, f"built-in lane {name!r} must be fused"
        c = pipe.cycles(1 << 20, 32, name)
        assert c["fill_cycles"] == float(pipe.stages)


def test_unfused_lane_pays_staged_fills_within_one_percent(rng):
    """A deliberately-unfused lane re-fills the pipeline per staged pass;
    at realistic sizes the fill is < 1% of the launch (degenerate
    unfused configs effectively unchanged)."""
    from repro.fabric import CodecLane, register_codec, unregister_codec
    from repro.fabric.codecs import GradientCodec
    from repro.sim import FlitPipeline

    @register_codec("fk_staged")
    class _Staged(GradientCodec):
        name = "fk_staged"
        bits_per_element = 1.0
        reduction = "vote"
        lane = CodecLane("sign_count")          # fused defaults to False

    try:
        pipe = FlitPipeline()
        c = pipe.cycles(1 << 20, 32, "fk_staged")
        assert c["fill_cycles"] == float(pipe.stages * pipe.unfused_passes)
        t_staged = pipe.t_agg(1 << 20, 32, "fk_staged")
        t_fused = pipe.t_agg(1 << 20, 32, "gbinary")
        assert t_staged > t_fused
        assert (t_staged - t_fused) / t_fused < 0.01
    finally:
        unregister_codec("fk_staged")


# ---------------------------------------------------------------------------
# import hygiene: kernels.ref is internal to the kernels package
# ---------------------------------------------------------------------------

def test_no_direct_kernels_ref_imports_outside_kernels_package():
    """Non-kernel modules consume the staged ops through kernels.ops (the
    interpret-dispatch seam) or the fused entry points — never the raw
    reference module (mirrors the CI grep gate)."""
    src = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    pat = re.compile(r"(from\s+[\w.]*kernels\s+import\s+[\w,\s]*\bref\b"
                     r"|[\w.]*kernels\.ref\b)")
    offenders = []
    for py in src.rglob("*.py"):
        if "kernels" in py.parts:
            continue
        for i, line in enumerate(py.read_text().splitlines(), 1):
            if pat.search(line) and not line.lstrip().startswith("#"):
                offenders.append(f"{py.relative_to(src)}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)
