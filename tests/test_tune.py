"""repro.tune — plan autotuning as compilation.

Covers the ISSUE-9 acceptance criteria:
  * `fabric.autotune` over ici_ring and multihop returns a TunedPlan
    whose sim-scored step time is <= every plan_presets() entry in its
    own search space (seeds are always sim-scored — structural);
  * the artifact JSON round-trips to a bit-identical re-scored plan;
  * a constraint pinning the classifier head to fp32 is respected in
    every emitted candidate;
  * the seventh registry (@register_search) behaves like the other six;
  * TunedPlan.install() round-trips through plan_presets by name;
  * the "tuned" controller re-ranks the shortlist from live Telemetry.
"""
import json

import jax
import pytest

from repro.core import AdmissionPlan, AggregationMode, GroupPolicy
from repro.core.buckets import DEFAULT_BUCKET_BYTES
from repro.core.modes import codec_name
from repro.fabric import Fabric
from repro.fabric.control import Telemetry, plan_presets
from repro.tune import (Candidate, CostModel, GridSearch,
                        MaxLowbitFraction, Objective, PinGroup, SearchSpace,
                        TunedPlan, TunedPlanController, autotune,
                        available_searches, default_space, get_search,
                        make_search, register_search, rescore,
                        unregister_search)

W = 8


def _params():
    """Quickstart-shaped abstract census: embed + backbone + norms + head."""
    sds, f32, d = jax.ShapeDtypeStruct, "float32", 128
    tree = {"wte": sds((2048, d), f32), "head_w": sds((d, 2048), f32)}
    for i in range(3):
        tree[f"h{i}"] = {"qkv": sds((d, 3 * d), f32),
                         "proj": sds((d, d), f32),
                         "fc_in": sds((d, 4 * d), f32),
                         "ln1_scale": sds((d,), f32)}
    return tree


@pytest.fixture(scope="module")
def fab():
    return Fabric(num_workers=W)


@pytest.fixture(scope="module")
def params():
    return _params()


# ---------------------------------------------------------------------------
# the seventh registry
# ---------------------------------------------------------------------------

def test_builtin_searches_registered():
    names = available_searches()
    for n in ("grid", "random", "successive_halving", "sha"):
        assert n in names
    assert get_search("sha") is get_search("successive_halving")
    assert isinstance(make_search("grid"), GridSearch)


def test_register_search_roundtrip_and_error_hint():
    @register_search("toy_search")
    class ToySearch:
        name = "toy_search"

        def search(self, candidates, model, objective, *, shortlist=8):
            return []

    try:
        assert isinstance(make_search("toy_search"), ToySearch)
    finally:
        unregister_search("toy_search")
    with pytest.raises(KeyError) as ei:
        get_search("toy_search")
    # the shared-registry error shape: available list + register hint
    msg = str(ei.value)
    assert "grid" in msg and "@register_search" in msg


# ---------------------------------------------------------------------------
# the space: enumeration, constraints, dedup
# ---------------------------------------------------------------------------

def test_space_enumerates_seeds_first_and_dedups(fab, params):
    space = SearchSpace(
        plans=(("gbin_backbone",
                AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)),),
        codecs=("gbinary",),
        bucket_bytes=(DEFAULT_BUCKET_BYTES,))
    cands = list(space.enumerate(fab.group_sizes(params)))
    # the generated gbinary plan collides with the seed -> deduped
    assert len(cands) == 1 and cands[0].seed
    assert cands[0].name.startswith("gbin_backbone/")


def test_pin_head_constraint_respected_in_every_candidate(fab, params):
    space = default_space()
    assert any(isinstance(c, PinGroup) and c.group == "head"
               for c in space.constraints)
    sizes = fab.group_sizes(params)
    cands = list(space.enumerate(sizes))
    assert cands, "default space admitted nothing"
    for c in cands:
        assert codec_name(c.plan.policy_for("head").mode) == "fp32", c.name
    # plans violating the pin (lowbit_all) are not in the space at all
    names = {c.name.split("/")[0] for c in cands}
    assert "lowbit_all" not in names and "gbin_packed_all" not in names
    assert "fp32" in names and "gbin_backbone" in names


def test_max_lowbit_fraction_constraint(fab, params):
    sizes = fab.group_sizes(params)
    lowbit = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    assert MaxLowbitFraction(1.0).admits(lowbit, sizes)
    assert not MaxLowbitFraction(0.0).admits(lowbit, sizes)
    assert MaxLowbitFraction(0.0).admits(AdmissionPlan.fp32_all(), sizes)
    backbone_frac = sizes["backbone"] / sum(sizes.values())
    assert MaxLowbitFraction(backbone_frac).admits(lowbit, sizes)


def test_generated_candidates_coerce_ef_off_for_non_ef_codecs(fab, params):
    space = SearchSpace(codecs=("int4", "gbinary"),
                        error_feedback=(True,))
    plans = dict(space._generated())
    assert plans["int4"].policy_for("backbone").error_feedback is False
    assert plans["gbinary+ef"].policy_for("backbone").error_feedback is True


def test_empty_space_raises():
    with pytest.raises(ValueError, match="empty SearchSpace"):
        SearchSpace()
    with pytest.raises(ValueError, match="bucket_bytes"):
        SearchSpace(codecs=("gbinary",), bucket_bytes=())


def test_space_signature_is_stable():
    a, b = default_space(), default_space()
    assert a.signature() == b.signature()
    assert "pin:head=fp32" in a.signature()


# ---------------------------------------------------------------------------
# cost model: two fidelities over one layout cache
# ---------------------------------------------------------------------------

def test_cost_model_bucket_bytes_changes_launch_count(fab, params):
    model = CostModel(fab, params, topology="ici_ring")
    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    small = Candidate("s", plan, bucket_bytes=64 * 1024)
    big = Candidate("b", plan, bucket_bytes=DEFAULT_BUCKET_BYTES)
    assert model.estimate(small).launches > model.estimate(big).launches
    assert model.estimates == 2
    score = model.simulate(big)
    assert model.simulations == 1
    assert score.step_time_s > 0 and score.wire_bytes > 0


def test_estimate_and_sim_agree_on_wire_bytes(fab, params):
    model = CostModel(fab, params, topology="ici_ring")
    cand = Candidate("c", AdmissionPlan.fp32_all())
    est, score = model.estimate(cand), model.simulate(cand)
    assert est.wire_bytes == pytest.approx(score.wire_bytes)
    assert est.launches == score.launches


def test_objective_scalarization_orders_by_weights():
    from repro.tune import CostEstimate
    fast_fat = CostEstimate(comm_time_s=1.0, wire_bytes=100.0,
                            launches=1, traffic_ratio=1.0)
    slow_thin = CostEstimate(comm_time_s=2.0, wire_bytes=1.0,
                             launches=1, traffic_ratio=1.0)
    assert Objective().of_estimate(fast_fat) < \
        Objective().of_estimate(slow_thin)
    heavy_wire = Objective(wire_byte_weight=1.0)
    assert heavy_wire.of_estimate(fast_fat) > \
        heavy_wire.of_estimate(slow_thin)


# ---------------------------------------------------------------------------
# acceptance: tuned >= no preset in its own space, on both topologies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["ici_ring", "multihop"])
@pytest.mark.parametrize("strategy", ["grid", "successive_halving"])
def test_autotune_beats_every_preset_in_space(fab, params, topology,
                                              strategy):
    space = default_space()
    tuned = fab.autotune(params, space, topology=topology,
                         strategy=strategy)
    # independently sim-score every preset in the space at every bucket
    # budget the space carries, through the same cost model constants
    model = CostModel(fab, params, topology=topology)
    obj = Objective.from_jsonable(tuned.provenance["objective"])
    for pname, plan in space.plans:
        if not space.admits(plan, model.sizes):
            continue
        for bb in space.bucket_bytes:
            score = model.simulate(Candidate(pname, plan, bucket_bytes=bb))
            assert obj.of_score(tuned.score) <= obj.of_score(score) + 1e-12, \
                (pname, bb)
    assert tuned.topology == topology
    assert tuned.num_workers == W
    assert codec_name(tuned.plan.policy_for("head").mode) == "fp32"


def test_autotune_respects_explicit_head_pin_everywhere(fab, params):
    tuned = fab.autotune(params, default_space(), topology="ici_ring")
    for r in tuned.runners_up:
        assert codec_name(r.plan.policy_for("head").mode) == "fp32", r.name


def test_autotune_unsatisfiable_constraints_raise(fab, params):
    space = SearchSpace(
        plans=(("gbin", AdmissionPlan.lowbit_backbone(
            AggregationMode.G_BINARY)),),
        constraints=(MaxLowbitFraction(0.0),))
    with pytest.raises(ValueError, match="no candidates"):
        fab.autotune(params, space)


# ---------------------------------------------------------------------------
# artifact: bit-identical round-trip, rescore, install
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tuned(fab, params):
    return fab.autotune(params, default_space(), topology="ici_ring")


def test_artifact_json_roundtrip_bit_identical(tuned, tmp_path):
    j = tuned.to_jsonable()
    back = TunedPlan.from_jsonable(json.loads(json.dumps(j)))
    assert back.to_jsonable() == j
    p = tuned.save(str(tmp_path / "tuned.json"))
    assert TunedPlan.load(p).to_jsonable() == j


def test_rescore_reproduces_artifact_bit_identically(tuned, fab, params,
                                                     tmp_path):
    loaded = TunedPlan.load(tuned.save(str(tmp_path / "t.json")))
    again = rescore(loaded, fab, params)
    assert again.to_jsonable() == tuned.to_jsonable()


def test_rescore_refuses_mismatched_model(tuned, fab):
    sds = jax.ShapeDtypeStruct
    with pytest.raises(ValueError, match="census mismatch"):
        rescore(tuned, fab, {"w": sds((3, 3), "float32")})


def test_rescore_refuses_mismatched_worker_count(tuned, params):
    with pytest.raises(ValueError, match="worker-count mismatch"):
        rescore(tuned, Fabric(num_workers=W * 2), params)


def test_artifact_version_guard():
    with pytest.raises(ValueError, match="newer"):
        TunedPlan.from_jsonable({"version": 999})


def test_artifact_signature_guard(tuned):
    j = tuned.to_jsonable()
    j["plan_signature"] = "tampered"
    with pytest.raises(ValueError, match="signature"):
        TunedPlan.from_jsonable(j)


def test_install_roundtrips_through_plan_presets(tuned):
    from repro.fabric.control import (StaticController,
                                      unregister_plan_preset)
    name = tuned.install("tuned_test_plan")
    try:
        assert name == "tuned_test_plan"
        assert plan_presets()[name].signature() == tuned.plan.signature()
        # resolvable by name anywhere presets are: StaticController
        ctl = StaticController(plan=name)
        assert ctl.plan.signature() == tuned.plan.signature()
    finally:
        unregister_plan_preset(name)
    assert name not in plan_presets()


def test_apply_adopts_bucket_budget(tuned, params):
    f = Fabric(num_workers=W, bucket_bytes=1234)
    plan = tuned.apply(f)
    assert f.bucket_bytes == tuned.bucket_bytes
    assert plan.signature() == tuned.plan.signature()


# ---------------------------------------------------------------------------
# online: the "tuned" controller re-ranks the shortlist from telemetry
# ---------------------------------------------------------------------------

def _telemetry(step, t):
    return Telemetry(step=step, loss=1.0, step_time_s=t)


def test_tuned_controller_holds_within_band(tuned):
    ctl = TunedPlanController(tuned, patience=2, tolerance=0.25)
    pred = ctl.predicted()
    for s in range(10):
        ctl.observe(_telemetry(s, pred))
    assert ctl.active == tuned.name and not ctl.events


def test_tuned_controller_retunes_on_sustained_misses(tuned):
    assert len(tuned.runners_up) > 0
    ctl = TunedPlanController(tuned, patience=3, tolerance=0.1)
    pred = ctl.predicted()
    plan0 = ctl.plan.signature()
    for s in range(6):
        ctl.observe(_telemetry(s, pred * 10))
    assert ctl.events and ctl.events[0].kind == "retune"
    assert ctl.active != tuned.name
    assert ctl.plan.signature() != plan0 or len(ctl._entries) == 1


def test_tuned_controller_ignores_other_bucket_budgets(tuned):
    ctl = TunedPlanController(tuned)
    eligible = {r.name for r in tuned.runners_up
                if r.score is not None
                and r.bucket_bytes == tuned.bucket_bytes}
    assert set(ctl._entries) == eligible | {tuned.name}


def test_tuned_controller_state_roundtrip(tuned):
    ctl = TunedPlanController(tuned, patience=1, tolerance=0.0)
    pred = ctl.predicted()
    for s in range(3):
        ctl.observe(_telemetry(s, pred * 10))
    state = json.loads(json.dumps(ctl.state_dict()))   # JSON-safe
    ctl2 = TunedPlanController(tuned)
    ctl2.load_state_dict(state)
    assert ctl2.active == ctl.active
    assert ctl2.plan.signature() == ctl.plan.signature()
    assert [e.kind for e in ctl2.events] == [e.kind for e in ctl.events]


def test_tuned_controller_registered_and_attachable(tuned, fab):
    ctl = fab.attach_controller("tuned", tuned=tuned)
    try:
        assert isinstance(ctl, TunedPlanController)
        assert ctl.plan.signature() == tuned.plan.signature()
    finally:
        fab.controller = None


def test_tuned_controller_validates_args(tuned):
    with pytest.raises(ValueError, match="patience"):
        TunedPlanController(tuned, patience=0)
    with pytest.raises(ValueError, match="alpha"):
        TunedPlanController(tuned, alpha=0.0)


# ---------------------------------------------------------------------------
# strategies: fidelity ladders keep the seed guarantee
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,kwargs", [
    ("grid", {}), ("random", {"samples": 4, "seed": 1}),
    ("successive_halving", {"eta": 3.0})])
def test_every_strategy_sim_scores_all_seeds(fab, params, strategy, kwargs):
    space = default_space()
    model = CostModel(fab, params, topology="ici_ring")
    cands = list(space.enumerate(model.sizes))
    scored = make_search(strategy, **kwargs).search(
        cands, model, Objective(), shortlist=2)
    by_sig = {s.candidate.signature(): s for s in scored}
    for c in cands:
        if c.seed:
            assert by_sig[c.signature()].score is not None, c.name
    # results are sorted: sim-certified block first, best objective first
    objs = [s.objective for s in scored if s.objective is not None]
    assert objs == sorted(objs)


def test_random_search_is_deterministic(fab, params):
    space = default_space()
    model = CostModel(fab, params, topology="ici_ring")
    cands = list(space.enumerate(model.sizes))
    a = make_search("random", samples=3, seed=7).search(
        cands, model, Objective(), shortlist=2)
    b = make_search("random", samples=3, seed=7).search(
        cands, model, Objective(), shortlist=2)
    assert [s.candidate.name for s in a] == [s.candidate.name for s in b]


def test_successive_halving_rejects_bad_eta():
    from repro.tune import SuccessiveHalving
    with pytest.raises(ValueError, match="eta"):
        SuccessiveHalving(eta=1.0)
