"""Hypothesis property tests on the system's invariants.

Properties proved over randomized inputs:
  * pack/unpack roundtrip preserves signs exactly;
  * the packed majority equals the dense Section-2 equations for any W;
  * majority is permutation-invariant in the worker axis;
  * unanimous workers always win the vote; flipping all signs negates u;
  * traffic accounting is a convex combination of per-mode ratios;
  * the CUSUM guard triggers on sustained growth and stays quiet on
    decreasing loss.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dependency (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro import kernels as K
from repro.kernels import ref
from repro.core import (AdmissionPlan, AggregationMode, CusumGuard,
                        GroupPolicy, bits_per_element, plan_traffic_ratio)

wstrat = st.integers(min_value=1, max_value=16)
rows = st.sampled_from([32, 64, 96])


@settings(max_examples=25, deadline=None)
@given(m=rows, seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(m, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(m, 128), jnp.float32)
    words = K.pack_signs(x)
    bits = ref.unpack_bits(words)
    np.testing.assert_array_equal(np.asarray(bits),
                                  (np.asarray(x) > 0).astype(np.uint32))


@settings(max_examples=25, deadline=None)
@given(w=wstrat, seed=st.integers(0, 2**31 - 1))
def test_packed_majority_equals_dense(w, seed):
    rng = np.random.RandomState(seed)
    n = 32 * 128
    grads = rng.randn(w, n).astype(np.float32)
    stack = jnp.stack([K.pack_signs(ref.to_plane(jnp.asarray(g)))
                       for g in grads])
    counts = K.popcount_stack(stack)
    sw, mw = K.majority_decode(counts, num_workers=w)
    u = ref.from_plane(K.unpack_ternary(sw, mw), n)
    want = ref.gbinary_aggregate_dense(jnp.asarray(grads))
    np.testing.assert_array_equal(np.asarray(u), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(w=st.integers(2, 8), seed=st.integers(0, 2**31 - 1),
       perm_seed=st.integers(0, 2**31 - 1))
def test_majority_permutation_invariant(w, seed, perm_seed):
    rng = np.random.RandomState(seed)
    grads = rng.randn(w, 32 * 128).astype(np.float32)
    perm = np.random.RandomState(perm_seed).permutation(w)
    a = ref.gbinary_aggregate_dense(jnp.asarray(grads))
    b = ref.gbinary_aggregate_dense(jnp.asarray(grads[perm]))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(w=wstrat, seed=st.integers(0, 2**31 - 1))
def test_unanimous_vote_and_sign_flip(w, seed):
    rng = np.random.RandomState(seed)
    base = np.abs(rng.randn(32 * 128)).astype(np.float32) + 1e-3
    grads = np.tile(base, (w, 1))
    u = np.asarray(ref.gbinary_aggregate_dense(jnp.asarray(grads)))
    assert np.all(u == 1.0)
    u_neg = np.asarray(ref.gbinary_aggregate_dense(jnp.asarray(-grads)))
    np.testing.assert_array_equal(u_neg, -u)


@settings(max_examples=30, deadline=None)
@given(nb=st.integers(1, 10**9), nh=st.integers(1, 10**7),
       mode=st.sampled_from([AggregationMode.G_BINARY,
                             AggregationMode.G_TERNARY]))
def test_traffic_ratio_convex_combination(nb, nh, mode):
    sizes = {"backbone": nb, "head": nh}
    plan = AdmissionPlan.from_dict(
        {"backbone": GroupPolicy(mode)},
        default=GroupPolicy(AggregationMode.FP32))
    r = plan_traffic_ratio(sizes, plan)
    fb = nb / (nb + nh)
    expect = fb * bits_per_element(mode) / 32.0 + (1 - fb) * 1.0
    assert math.isclose(r, expect, rel_tol=1e-12)
    assert bits_per_element(mode) / 32.0 <= r <= 1.0


@settings(max_examples=20, deadline=None)
@given(start=st.floats(0.5, 5.0), slope=st.floats(0.01, 0.2))
def test_cusum_triggers_on_sustained_growth(start, slope):
    g = CusumGuard(kappa=0.005, h=0.2)
    triggered = False
    for i in range(200):
        if g.update(start + slope * i):
            triggered = True
            break
    assert triggered


@settings(max_examples=20, deadline=None)
@given(start=st.floats(0.5, 5.0), decay=st.floats(0.9, 0.999),
       noise_seed=st.integers(0, 2**31 - 1))
def test_cusum_quiet_on_decreasing_loss(start, decay, noise_seed):
    rng = np.random.RandomState(noise_seed)
    g = CusumGuard(kappa=0.01, h=0.25)
    loss = start
    for _ in range(200):
        loss *= decay
        assert not g.update(loss + abs(rng.randn()) * 1e-4)


@settings(max_examples=10, deadline=None)
@given(m=rows, phase=st.integers(0, 2))
def test_ternary_gate_keeps_two_of_three(m, phase):
    words = ref.ternary_gate_words(m, phase=phase)
    bits = np.asarray(ref.unpack_bits(words)).reshape(-1)
    idx = np.arange(bits.size)
    np.testing.assert_array_equal(bits, ((idx + phase) % 3 != 2))
    kept = bits.mean()
    assert abs(kept - 2 / 3) < 1e-3
