"""Hypothesis property tests on the system's invariants.

Properties proved over randomized inputs:
  * pack/unpack roundtrip preserves signs exactly;
  * the packed majority equals the dense Section-2 equations for any W;
  * majority is permutation-invariant in the worker axis;
  * unanimous workers always win the vote; flipping all signs negates u;
  * traffic accounting is a convex combination of per-mode ratios;
  * the CUSUM guard triggers on sustained growth and stays quiet on
    decreasing loss.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dependency (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro import kernels as K
from repro.kernels import ref
from repro.core import (AdmissionPlan, AggregationMode, CusumGuard,
                        GroupPolicy, bits_per_element, plan_traffic_ratio)

wstrat = st.integers(min_value=1, max_value=16)
rows = st.sampled_from([32, 64, 96])


@settings(max_examples=25, deadline=None)
@given(m=rows, seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(m, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(m, 128), jnp.float32)
    words = K.pack_signs(x)
    bits = ref.unpack_bits(words)
    np.testing.assert_array_equal(np.asarray(bits),
                                  (np.asarray(x) > 0).astype(np.uint32))


@settings(max_examples=25, deadline=None)
@given(w=wstrat, seed=st.integers(0, 2**31 - 1))
def test_packed_majority_equals_dense(w, seed):
    rng = np.random.RandomState(seed)
    n = 32 * 128
    grads = rng.randn(w, n).astype(np.float32)
    stack = jnp.stack([K.pack_signs(ref.to_plane(jnp.asarray(g)))
                       for g in grads])
    counts = K.popcount_stack(stack)
    sw, mw = K.majority_decode(counts, num_workers=w)
    u = ref.from_plane(K.unpack_ternary(sw, mw), n)
    want = ref.gbinary_aggregate_dense(jnp.asarray(grads))
    np.testing.assert_array_equal(np.asarray(u), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(w=st.integers(2, 8), seed=st.integers(0, 2**31 - 1),
       perm_seed=st.integers(0, 2**31 - 1))
def test_majority_permutation_invariant(w, seed, perm_seed):
    rng = np.random.RandomState(seed)
    grads = rng.randn(w, 32 * 128).astype(np.float32)
    perm = np.random.RandomState(perm_seed).permutation(w)
    a = ref.gbinary_aggregate_dense(jnp.asarray(grads))
    b = ref.gbinary_aggregate_dense(jnp.asarray(grads[perm]))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(w=wstrat, seed=st.integers(0, 2**31 - 1))
def test_unanimous_vote_and_sign_flip(w, seed):
    rng = np.random.RandomState(seed)
    base = np.abs(rng.randn(32 * 128)).astype(np.float32) + 1e-3
    grads = np.tile(base, (w, 1))
    u = np.asarray(ref.gbinary_aggregate_dense(jnp.asarray(grads)))
    assert np.all(u == 1.0)
    u_neg = np.asarray(ref.gbinary_aggregate_dense(jnp.asarray(-grads)))
    np.testing.assert_array_equal(u_neg, -u)


@settings(max_examples=30, deadline=None)
@given(nb=st.integers(1, 10**9), nh=st.integers(1, 10**7),
       mode=st.sampled_from([AggregationMode.G_BINARY,
                             AggregationMode.G_TERNARY]))
def test_traffic_ratio_convex_combination(nb, nh, mode):
    sizes = {"backbone": nb, "head": nh}
    plan = AdmissionPlan.from_dict(
        {"backbone": GroupPolicy(mode)},
        default=GroupPolicy(AggregationMode.FP32))
    r = plan_traffic_ratio(sizes, plan)
    fb = nb / (nb + nh)
    expect = fb * bits_per_element(mode) / 32.0 + (1 - fb) * 1.0
    assert math.isclose(r, expect, rel_tol=1e-12)
    assert bits_per_element(mode) / 32.0 <= r <= 1.0


@settings(max_examples=20, deadline=None)
@given(start=st.floats(0.5, 5.0), slope=st.floats(0.01, 0.2))
def test_cusum_triggers_on_sustained_growth(start, slope):
    g = CusumGuard(kappa=0.005, h=0.2)
    triggered = False
    for i in range(200):
        if g.update(start + slope * i):
            triggered = True
            break
    assert triggered


@settings(max_examples=20, deadline=None)
@given(start=st.floats(0.5, 5.0), decay=st.floats(0.9, 0.999),
       noise_seed=st.integers(0, 2**31 - 1))
def test_cusum_quiet_on_decreasing_loss(start, decay, noise_seed):
    rng = np.random.RandomState(noise_seed)
    g = CusumGuard(kappa=0.01, h=0.25)
    loss = start
    for _ in range(200):
        loss *= decay
        assert not g.update(loss + abs(rng.randn()) * 1e-4)


@settings(max_examples=10, deadline=None)
@given(m=rows, phase=st.integers(0, 2))
def test_ternary_gate_keeps_two_of_three(m, phase):
    words = ref.ternary_gate_words(m, phase=phase)
    bits = np.asarray(ref.unpack_bits(words)).reshape(-1)
    idx = np.arange(bits.size)
    np.testing.assert_array_equal(bits, ((idx + phase) % 3 != 2))
    kept = bits.mean()
    assert abs(kept - 2 / 3) < 1e-3


# ---------------------------------------------------------------------------
# pack/unpack plane layout on ragged sizes (padding/truncation edges the
# fused bucket path relies on)
# ---------------------------------------------------------------------------

#: deliberately awkward sizes: 1, sub-tile, off-by-one around the
#: LANE*32 tile boundary, and multi-tile ragged tails
ragged_n = st.one_of(
    st.integers(1, 2 * ref.TILE + 1),
    st.sampled_from([ref.TILE - 1, ref.TILE, ref.TILE + 1,
                     2 * ref.TILE - 1, 3 * ref.TILE + 17, ref.LANE + 3]))


@settings(max_examples=40, deadline=None)
@given(n=ragged_n, seed=st.integers(0, 2**31 - 1))
def test_plane_roundtrip_any_size(n, seed):
    """to_plane zero-pads to the canonical tile; from_plane drops exactly
    the padding — a lossless round trip for every ragged size."""
    rng = np.random.RandomState(seed)
    flat = jnp.asarray(rng.randn(n), jnp.float32)
    plane = ref.to_plane(flat)
    assert plane.shape == (ref.padded_len(n) // ref.LANE, ref.LANE)
    assert plane.shape[0] % ref.PACK == 0          # word-plane compatible
    back = ref.from_plane(plane, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))
    # the padding region is exactly zero (sign bit 0 = non-positive)
    pad = np.asarray(plane).reshape(-1)[n:]
    assert not pad.size or not pad.any()


@settings(max_examples=40, deadline=None)
@given(n=ragged_n, seed=st.integers(0, 2**31 - 1))
def test_sign_pack_roundtrip_ragged(n, seed):
    """pack_signs on a ragged payload: the first n bits are the signs,
    every padding bit is 0 (zero padding is non-positive)."""
    rng = np.random.RandomState(seed)
    flat = jnp.asarray(rng.randn(n), jnp.float32)
    words = ref.sign_pack(ref.to_plane(flat))
    bits = np.asarray(ref.unpack_bits(words)).reshape(-1)
    np.testing.assert_array_equal(bits[:n],
                                  (np.asarray(flat) > 0).astype(np.uint32))
    assert not bits[n:].any()


@settings(max_examples=40, deadline=None)
@given(n=ragged_n, seed=st.integers(0, 2**31 - 1),
       extra_rows=st.integers(0, 3))
def test_gate_words_from_mask_roundtrip_ragged(n, seed, extra_rows):
    """gate_words_from_mask on sizes not a multiple of the word-plane
    tile: bits [0, n) reproduce the mask, canonical padding keeps = 1,
    and pad_words right-pads with all-ones rows (the all_to_all row
    padding of the fused packed schedule)."""
    rng = np.random.RandomState(seed)
    keep = rng.rand(n) < 0.5
    base_rows = ref.padded_len(n) // ref.LANE // ref.PACK
    pad_words = base_rows + extra_rows
    words = ref.gate_words_from_mask(keep, pad_words=pad_words)
    assert words.shape == (pad_words, ref.LANE)
    bits = np.asarray(ref.unpack_bits(words)).reshape(-1)
    np.testing.assert_array_equal(bits[:n], keep.astype(np.uint32))
    # canonical padding and pad_words rows all keep (gate never zeroes
    # out-of-payload elements — unpack drops them, value irrelevant)
    assert bits[n:].all()


@settings(max_examples=25, deadline=None)
@given(n=ragged_n, phase=st.integers(0, 2), seed=st.integers(0, 2**31 - 1))
def test_gate_words_match_bucket_gate_mask(n, phase, seed):
    """The packed gate words and the BucketGate host mask/device vector
    agree bit-for-bit on ragged per-leaf segments — the invariant that
    keeps the fused ternary path identical across schedules."""
    from repro.core.buckets import BucketGate
    n2 = max(1, n // 2)
    gate = BucketGate(segments=((n, phase), (n2, phase)))
    mask = gate.mask()
    assert mask.shape == (n + n2,)
    words = ref.gate_words_from_mask(mask)
    bits = np.asarray(ref.unpack_bits(words)).reshape(-1)
    np.testing.assert_array_equal(bits[:n + n2], mask.astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(gate.vector(jnp.float32)), mask.astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), perm_seed=st.integers(0, 2**31 - 1),
       bucket_bytes=st.sampled_from([1, 4096, 256 * 1024]))
def test_bucket_layout_insertion_order_invariant(seed, perm_seed,
                                                 bucket_bytes):
    """plan_buckets is a pure function of the *canonical* tree, not of
    dict insertion order: permuting the order keys were inserted in
    yields a bit-identical BucketLayout (pytree flattening sorts dict
    keys, and the planner adds no ordering of its own)."""
    import jax

    from repro.core import plan_buckets, resolve_policies

    rng = np.random.RandomState(seed)
    sds = jax.ShapeDtypeStruct
    names = ["wte", "head_w", "ln_scale", "h00/qkv", "h00/proj",
             "h01/fc_in", "h01/fc_out", "bias"]
    shapes = [(rng.randint(1, 64), rng.randint(1, 64)) for _ in names]
    tree = {n: sds(s, "float32") for n, s in zip(names, shapes)}

    perm = np.random.RandomState(perm_seed).permutation(len(names))
    permuted = {}
    for i in perm:
        permuted[names[i]] = tree[names[i]]
    assert list(permuted) != list(tree) or (perm == np.arange(
        len(names))).all()

    plan = AdmissionPlan.lowbit_backbone(AggregationMode.G_BINARY)
    layouts = []
    for t in (tree, permuted):
        policies = resolve_policies(t, plan)
        layouts.append(plan_buckets(t, policies,
                                    bucket_bytes=bucket_bytes))
    assert layouts[0] == layouts[1]
