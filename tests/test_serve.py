"""repro.serve: paged KV cache, continuous batching, codec-priced KV.

The load-bearing contract is *bit-identity*: continuous batching, block
paging, preemption and CXL spill round-trips must be invisible to each
request's numerics — its logits match the unbatched decode path exactly
(fp32 KV codec).  Allocator/evictor invariants are property-tested with
hypothesis; the decode timeline replays through ``repro.sim`` on both
CXL topologies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_cache, init_params
from repro.runtime.serve import build_cached_prefill, build_serve_step
from repro.serve import (BlockAllocator, NoFreeBlocks, PagedKVCache,
                         Request, ServeEngine, Scheduler, get_policy,
                         register_policy, unregister_policy)


def toy_cfg(**kw):
    base = dict(name="toy", family="dense", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97,
                dtype="float32", remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def served():
    """One staggered multi-request trace through the engine, plus params."""
    cfg = toy_cfg()
    eng = ServeEngine(cfg, max_batch=3, max_seq=32, num_blocks=16,
                      block_size=4, kv_codec="fp32", collect_logits=True)
    trace = [{"prompt": [3, 5, 7], "max_new_tokens": 6},
             {"prompt": [11, 2], "max_new_tokens": 5, "arrival_step": 1},
             {"prompt": [1, 4, 1, 5, 9], "max_new_tokens": 4,
              "arrival_step": 2}]
    outputs = eng.serve(trace)
    return cfg, eng, trace, outputs


# ---------------------------------------------------------------------------
# allocator / evictor invariants
# ---------------------------------------------------------------------------

def test_allocator_free_list_and_refcounts():
    a = BlockAllocator(4)
    b0, b1 = a.allocate(), a.allocate()
    assert a.num_in_use == 2 and a.num_free == 2
    assert a.ref_count(b0) == 1
    a.fork(b0)
    assert a.ref_count(b0) == 2
    assert a.free(b0) is False          # still one holder
    assert a.free(b0) is True
    assert a.num_in_use == 1
    with pytest.raises(ValueError, match="double free"):
        a.free(b0)
    a.free(b1)
    assert a.num_free == 4


def test_allocator_fork_copy_on_write_round_trip():
    """Prefix-sharing contract for ``fork``: one more holder, no copy —
    a shared block survives any non-final free and is released (and
    LIFO-reused) only when the last holder drops it."""
    a = BlockAllocator(3)
    parent = a.allocate()
    other = a.allocate()
    child = a.fork(parent)
    # fork hands back the same physical block (copy-on-write-free share)
    assert child == parent and a.ref_count(parent) == 2
    assert a.stats.forks == 1
    # the first holder's free drops a reference but must not release
    assert a.free(parent) is False
    assert a.ref_count(parent) == 1 and a.num_in_use == 2
    assert a.stats.releases == 0
    # the last holder's free releases the block back to the pool...
    assert a.free(child) is True
    assert a.stats.releases == 1 and a.num_in_use == 1
    # ...and the LIFO free list reuses the cache-warm block first
    assert a.allocate() == parent
    assert a.ref_count(parent) == 1
    # a released block cannot be forked back to life
    a.free(other)
    with pytest.raises(ValueError, match="cannot fork unallocated"):
        a.fork(other)
    assert a.ref_count(other) == 0


def test_allocator_exhaustion_raises():
    a = BlockAllocator(2)
    a.allocate(), a.allocate()
    with pytest.raises(NoFreeBlocks):
        a.allocate()


def test_serve_property_invariants():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="optional test dependency (pip install .[test])")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 5), min_size=1, max_size=40),
           num_blocks=st.integers(1, 8))
    def allocator_never_leaks_or_double_frees(ops, num_blocks):
        """Random allocate/fork/free interleavings keep every block
        either free or refcounted >= 1 — and counts always add up."""
        a = BlockAllocator(num_blocks)
        held = []
        for op in ops:
            if op <= 2:                      # allocate
                try:
                    held.append(a.allocate())
                except NoFreeBlocks:
                    assert a.num_free == 0
            elif op == 3 and held:           # fork
                held.append(a.fork(held[0]))
            elif held:                       # free
                bid = held.pop()
                a.free(bid)
            assert a.num_free + a.num_in_use == num_blocks
            assert all(a.ref_count(b) >= 1 for b in held)
        for bid in held:
            a.free(bid)
        assert a.num_free == num_blocks

    @settings(max_examples=25, deadline=None)
    @given(lengths=st.lists(st.integers(1, 23), min_size=1, max_size=6),
           block_size=st.sampled_from([1, 3, 4, 8]))
    def cache_capacity_roundtrips_at_ragged_lengths(lengths, block_size):
        """ensure_capacity + release round-trips the pool for any ragged
        token counts (ceil-div block math, no leaked blocks)."""
        cfg = toy_cfg()
        cache = PagedKVCache(cfg, num_blocks=64, block_size=block_size)
        for rid, n in enumerate(lengths):
            cache.add_request(rid)
            cache.ensure_capacity(rid, n)
            want = -(-n // block_size)
            assert len(cache._tables[rid]) == want
        assert cache.blocks_in_use == sum(-(-n // block_size)
                                          for n in lengths)
        for rid in range(len(lengths)):
            cache.release(rid)
        assert cache.blocks_in_use == 0

    allocator_never_leaks_or_double_frees()
    cache_capacity_roundtrips_at_ragged_lengths()


def test_cache_spill_fetch_roundtrip_is_lossless(rng):
    """Evicting a cold block to the CXL tier and fetching it back
    reproduces the stored values bit-for-bit (fp32 and int4)."""
    cfg = toy_cfg()
    for codec in ("fp32", "int4"):
        cache = PagedKVCache(cfg, num_blocks=2, block_size=4,
                             kv_codec=codec)
        k = rng.randn(cfg.num_layers, 4, cfg.num_kv_heads,
                      cfg.hd).astype(np.float32)
        v = rng.randn(*k.shape).astype(np.float32)
        cache.add_request(0)
        cache.write_prompt(0, k, v)
        before_k = np.zeros((cfg.num_layers, 8, cfg.num_kv_heads, cfg.hd),
                            np.float32)
        before_v = np.zeros_like(before_k)
        cache.gather_into(0, before_k, before_v)
        cache.deactivate(0, tick=1)
        # two new requests squeeze request 0 fully out of the pool
        for rid in (1, 2):
            cache.add_request(rid)
            cache.ensure_capacity(rid, 4)
        assert cache.tier.spills == 1
        cache.release(1), cache.release(2)
        assert cache.activate(0, tick=2)
        assert cache.tier.fetches == 1
        after_k = np.zeros_like(before_k)
        after_v = np.zeros_like(before_v)
        cache.gather_into(0, after_k, after_v)
        np.testing.assert_array_equal(before_k, after_k)
        np.testing.assert_array_equal(before_v, after_v)


def test_kv_codec_must_declare_kv_cache():
    with pytest.raises(ValueError, match="kv_cache"):
        PagedKVCache(toy_cfg(), num_blocks=4, block_size=4,
                     kv_codec="gbinary")


# ---------------------------------------------------------------------------
# bit-identity: continuous batching == unbatched decode
# ---------------------------------------------------------------------------

def test_vector_positions_match_scalar_unbatched_path():
    """(B,) positions at B=1 reproduce the scalar build_serve_step path
    bit-for-bit — the engine's decode is literally the unbatched one."""
    cfg = toy_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prefill = build_cached_prefill(cfg, donate=False)
    step, _ = build_serve_step(cfg, batch=1, max_seq=16, donate=False)
    cache = init_cache(cfg, 1, 16, dtype=jnp.float32)
    logits, cache = prefill(params, jnp.asarray([[3, 5, 7, 0]], jnp.int32),
                            jnp.int32(3), cache)
    tok = jnp.argmax(logits, -1).reshape(1, 1).astype(jnp.int32)
    l_s, c_s = step(params, tok, cache, jnp.int32(3))
    l_v, c_v = step(params, tok, cache, jnp.asarray([3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_continuous_batching_bit_identical_per_request(served):
    """Each request in the staggered, mixed-length batched trace gets
    exactly the logits it would get served alone (no cross-row leakage
    through batching, paging, gather/scatter, or admission order)."""
    cfg, eng, trace, outputs = served
    for rid, entry in enumerate(trace):
        solo = ServeEngine(cfg, params=eng.params, max_batch=3, max_seq=32,
                           num_blocks=16, block_size=4,
                           collect_logits=True)
        got = solo.serve([{"prompt": entry["prompt"],
                           "max_new_tokens": entry["max_new_tokens"]}])
        assert got[0] == outputs[rid]
        assert len(solo.logits[0]) == len(eng.logits[rid])
        for a, b in zip(solo.logits[0], eng.logits[rid]):
            np.testing.assert_array_equal(a, b)


def test_preemption_and_resume_preserve_bits():
    """A pool too small for two requests forces preemption + CXL spill;
    the preempted request resumes and still matches its solo run."""
    cfg = toy_cfg()
    eng = ServeEngine(cfg, max_batch=2, max_seq=16, num_blocks=6,
                      block_size=2, collect_logits=True)
    outputs = eng.serve([
        {"prompt": [3, 5, 7], "max_new_tokens": 8},
        {"prompt": [11, 2, 6], "max_new_tokens": 8, "arrival_step": 1}])
    tl = eng.timeline()
    assert tl.total_preemptions > 0
    assert eng.cache.tier.spills > 0 and eng.cache.tier.fetches > 0
    assert eng.cache.blocks_in_use == 0          # fully drained
    for rid, prompt in ((0, [3, 5, 7]), (1, [11, 2, 6])):
        solo = ServeEngine(cfg, params=eng.params, max_batch=2, max_seq=16,
                           num_blocks=16, block_size=2,
                           collect_logits=True)
        got = solo.serve([{"prompt": prompt, "max_new_tokens": 8}])
        assert got[0] == outputs[rid]
        for a, b in zip(solo.logits[0], eng.logits[rid]):
            np.testing.assert_array_equal(a, b)
    preempted = [r for r in eng.requests.values() if r.preemptions][0]
    assert preempted.state.value == "finished"


# ---------------------------------------------------------------------------
# codec-quantized KV
# ---------------------------------------------------------------------------

def test_int4_kv_codec_prices_and_quantizes(served):
    cfg, eng, trace, _ = served
    e4 = ServeEngine(cfg, params=eng.params, max_batch=3, max_seq=32,
                     num_blocks=16, block_size=4, kv_codec="int4")
    out4 = e4.serve([dict(e) for e in trace])
    assert all(len(v) == e["max_new_tokens"]
               for v, e in zip(out4.values(), trace))
    t32, t4 = eng.timeline(), e4.timeline()
    assert t4.kv_codec == "int4" and t32.kv_codec == "fp32"
    # same token traffic, 8x cheaper wire price (4 vs 32 bits/element)
    assert t4.total_wire_bytes < t32.total_wire_bytes / 7.5
    # absmax quantization is idempotent at write-fragment granularity:
    # re-encoding an encoded fragment reproduces it bit-for-bit, so
    # repeated spill/gather round trips cannot compound error
    from repro.fabric.codecs import get_codec
    codec = get_codec("int4")
    frag = np.random.RandomState(3).randn(2, 4, 2, 8).astype(np.float32)
    once = codec.kv_encode(frag)
    np.testing.assert_array_equal(codec.kv_encode(once), once)
    assert not np.array_equal(once, frag)        # it did quantize


def test_unsupported_family_rejected():
    from repro.models.config import SsmConfig
    cfg = toy_cfg(family="ssm", d_ff=0, ssm=SsmConfig(state_size=8))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, max_batch=1, max_seq=8, num_blocks=4, block_size=4)


# ---------------------------------------------------------------------------
# scheduler policies
# ---------------------------------------------------------------------------

def test_policy_registry_and_admission_order():
    reqs = [Request(rid=0, prompt=[1], max_new_tokens=9, arrival_step=0),
            Request(rid=1, prompt=[1], max_new_tokens=2, arrival_step=1),
            Request(rid=2, prompt=[1], max_new_tokens=5, arrival_step=2)]
    fcfs = get_policy("fcfs")
    sjf = get_policy("sjf")
    assert [r.rid for r in fcfs.admission_order(reqs)] == [0, 1, 2]
    assert [r.rid for r in sjf.admission_order(reqs)] == [1, 2, 0]
    assert fcfs.preemption_victim(reqs).rid == 2     # youngest arrival
    assert sjf.preemption_victim(reqs).rid == 0      # longest remaining

    @register_policy("toy_lifo")
    class Lifo:
        name = "toy_lifo"

        def admission_order(self, waiting):
            return sorted(waiting, key=lambda r: -r.arrival_step)

        def preemption_victim(self, running):
            return running[0]

    try:
        s = Scheduler(max_batch=2, policy="toy_lifo")
        for r in reqs:
            s.add(r)
        assert [r.rid for r in s.admissible(now_step=5)] == [2, 1]
    finally:
        unregister_policy("toy_lifo")
    with pytest.raises(KeyError, match="unknown serve policy 'nope'"):
        get_policy("nope")


def test_sjf_policy_serves_trace():
    cfg = toy_cfg()
    eng = ServeEngine(cfg, max_batch=2, max_seq=16, num_blocks=12,
                      block_size=2, policy="sjf")
    outs = eng.serve([{"prompt": [3, 1], "max_new_tokens": 6},
                      {"prompt": [2, 7], "max_new_tokens": 2},
                      {"prompt": [5], "max_new_tokens": 4}])
    assert [len(v) for v in outs.values()] == [6, 2, 4]


# ---------------------------------------------------------------------------
# sim replay of the decode timeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["cxl_direct", "cxl_switched"])
def test_simulate_replays_decode_timeline(served, topology):
    cfg, eng, trace, _ = served
    tl = eng.timeline()
    rep = eng.simulate(topology=topology, step_compute_s=1e-4)
    assert rep.topology == topology
    assert rep.num_launches == tl.num_steps
    assert rep.step_time_s >= tl.num_steps * 1e-4
    np.testing.assert_allclose(
        sum(l.wire_bytes for l in rep.launches), tl.total_wire_bytes)
    # later steps must not start before their model forward finished
    for l in rep.launches:
        assert l.start_s >= l.ready_s
    assert rep.to_jsonable()["num_launches"] == tl.num_steps


def test_timeline_jsonable_and_records(served):
    cfg, eng, trace, outputs = served
    tl = eng.timeline()
    d = tl.to_jsonable()
    assert d["total_new_tokens"] == sum(len(v) for v in outputs.values())
    assert len(d["steps"]) == tl.num_steps
    assert all(s["utilization"] <= 1.0 for s in d["steps"])
    # staggered arrivals: request 2 enters after step 2, batch grows
    admitted = {rid: s["step"] for s in d["steps"] for rid in s["admitted"]}
    assert admitted[0] == 0 and admitted[1] >= 1 and admitted[2] >= 2
