"""Optimizer unit tests: AdamW reference math, schedules, ZeRO-1 specs."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim import AdamW, SgdMomentum, lr_schedule, optimizer_state_pspecs


def test_lr_schedule_warmup_and_decay():
    f = lambda s: float(lr_schedule(s, peak_lr=1.0, warmup_steps=10,
                                    total_steps=110, min_ratio=0.1))
    assert f(0) == 0.0
    assert abs(f(5) - 0.5) < 1e-6
    assert abs(f(10) - 1.0) < 1e-6
    assert f(60) < f(10)
    assert abs(f(110) - 0.1) < 1e-3          # floors at min_ratio


def test_adamw_matches_reference_step():
    opt = AdamW(peak_lr=1e-2, warmup_steps=0, total_steps=10**9, b1=0.9,
                b2=0.999, eps=1e-8)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    st = opt.init(p)
    p1, st1 = opt.apply(p, g, st)
    # hand-computed: m=0.1g/0.1, v=0.001g^2/0.001 -> delta=g/|g| scaled
    m = 0.1 * np.asarray(g["w"]) / (1 - 0.9)
    v = 0.001 * np.asarray(g["w"]) ** 2 / (1 - 0.999)
    want = np.asarray(p["w"]) - 1e-2 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)
    assert int(st1.step) == 1


def test_sgd_momentum_accumulates():
    opt = SgdMomentum(peak_lr=0.1, warmup_steps=0, total_steps=10**9,
                      momentum=0.5)
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.ones(3)}
    st = opt.init(p)
    p1, st1 = opt.apply(p, g, st)
    p2, st2 = opt.apply(p1, g, st1)
    # v1=1, v2=1.5 -> p after two steps = -(0.1 + 0.15)
    np.testing.assert_allclose(np.asarray(p2["w"]), -0.25, rtol=1e-6)


def test_zero1_specs_shard_first_divisible_dim():
    params = {"big": jnp.zeros((64, 32)), "tp": jnp.zeros((64, 32)),
              "tiny": jnp.zeros((3,)), "scalar": jnp.zeros(())}
    pspecs = {"big": P(), "tp": P(None, "model"), "tiny": P(), "scalar": P()}
    out = optimizer_state_pspecs(pspecs, params, dp_axes=("data",),
                                 dp_size=8, zero1=True)
    assert out["big"] == P(("data",), None)           # dim0 64 % 8 == 0
    assert out["tp"] == P(("data",), "model")         # keeps TP sharding
    assert out["tiny"] == P(None)                     # 3 not divisible
    off = optimizer_state_pspecs(pspecs, params, dp_size=8, zero1=False)
    assert off["big"] == P()


def test_has_nu_derived_from_init_state():
    """`has_nu` introspects the actual init state, so subclasses and new
    adaptive optimizers classify correctly without name sniffing."""
    class Lion(SgdMomentum):             # adaptive-naming decoy, no nu
        pass

    class WarmAdamW(AdamW):              # AdamW subclass keeps its nu
        pass

    assert AdamW().has_nu and WarmAdamW().has_nu
    assert not SgdMomentum().has_nu and not Lion().has_nu
